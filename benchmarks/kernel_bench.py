"""Pallas kernel micro-bench + the CI kernel correctness gate.

Two jobs in one script:

  * timings — median us/call for every kernel and every serving-matmul
    dispatch backend. On CPU the kernels run in interpret mode (emulation),
    so timings are informational only; on TPU (``kernels.ops.on_tpu()``)
    the same script measures the REAL kernels (interpret=False).
  * ``--check`` — gate the platform-independent invariants against the
    committed baseline (benchmarks/baselines/kernel_bench.json): backend
    parity (ref / fused / packed bit-identical through repro.kernels.
    dispatch; raw kernels vs the jnp oracles), artifact shapes, and HBM
    bytes per weight per layout. Any parity or shape/HBM drift hard-fails;
    timing drift never does. Refresh the baseline by copying
    benchmarks/results/kernel_bench.json over it when the kernels
    legitimately change.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.common import emit, save_json, time_call  # noqa: E402
from repro import configs  # noqa: E402
from repro.kernels import dispatch, ops, ref  # noqa: E402
from repro.kernels import pann_matmul as _pm  # noqa: E402
from repro.kernels.pann_matmul_packed import (pack_planes,  # noqa: E402
                                              pann_matmul_packed)
from repro.models.serving import quantize_params_for_serving  # noqa: E402

BASELINE = os.path.join(os.path.dirname(__file__), "baselines",
                        "kernel_bench.json")


def _exact(a, b) -> dict:
    """Parity record: bit-identical flag + max abs diff (0.0 when exact)."""
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return {"exact": bool((a == b).all()),
            "max_abs_diff": float(np.abs(a - b).max())}


def run(check: bool = False) -> dict:
    interpret = not ops.on_tpu()     # measure REAL kernels on TPU
    rng = np.random.default_rng(0)
    m, k, n = 256, 512, 256
    x = jnp.abs(jnp.asarray(rng.standard_normal((m, k)), jnp.float32))
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    packed = ops.pann_pack_weights(w, r=2.0)
    p_cnt = int(packed["n_planes"])
    timings: dict[str, float] = {}

    us = time_call(lambda: ops.pann_matmul(x, packed, act_bits=8,
                                           interpret=interpret))
    timings["pann_matmul_fused"] = us
    emit("kernel_pann_matmul_fused", us, f"{m}x{k}x{n} int8 bitplane")

    us = time_call(lambda: ops.pann_matmul(x, packed, act_bits=8,
                                           mode="planes",
                                           interpret=interpret))
    timings["pann_matmul_planes"] = us
    emit("kernel_pann_matmul_planes", us, "literal Eq.10 dataflow")

    x_q = jnp.asarray(rng.integers(0, 127, (m, k)), jnp.int8)
    w_q = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8)
    s_x = jnp.ones((m, 1), jnp.float32)
    s_w = jnp.ones((n,), jnp.float32)
    us = time_call(lambda: ops.unsigned_matmul(x_q, w_q, s_x, s_w,
                                               interpret=interpret))
    timings["unsigned_matmul"] = us
    emit("kernel_unsigned_matmul", us, "Sec.4 split, int32 accum")

    us = time_call(lambda: ops.quantize_act(x, bits=8, interpret=interpret))
    timings["quantize_act"] = us
    emit("kernel_quantize_act", us, "per-row scale + round + clip")

    us = time_call(lambda: ref.quantize_act_ref(x, 8))
    timings["quantize_act_ref"] = us
    emit("kernel_quantize_act_ref", us, "jnp oracle")

    pp = pack_planes(packed["planes_pos"])
    pn = pack_planes(packed["planes_neg"])
    x_q2 = jnp.asarray(rng.integers(0, 128, (m, k)), jnp.int8)
    us = time_call(lambda: pann_matmul_packed(
        x_q2, pp, pn, s_x, packed["gamma"], interpret=interpret))
    timings["pann_matmul_packed"] = us
    emit("kernel_pann_matmul_packed", us,
         f"{p_cnt} planes at 1 bit/weight HBM")

    # --- the dispatch backends (the serving hot path) -----------------------
    cfg = configs.reduced(configs.get_config("llama3-8b"))
    leaf = quantize_params_for_serving(
        {"wq": {"w": w}}, cfg, r=2.0, act_bits=8, pack_planes=True)["wq"]
    xs = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    backends = ("ref", "fused" + (":force" if interpret else ""),
                "packed" + (":force" if interpret else ""))
    disp = {}
    for spec in backends:
        name = spec.split(":")[0]
        us = time_call(lambda spec=spec: dispatch.serving_linear(
            xs, leaf, spec))
        timings[f"dispatch_{name}"] = us
        disp[name] = np.asarray(dispatch.serving_linear(xs, leaf, spec))
        emit(f"kernel_dispatch_{name}", us, "serving_linear backend")

    # --- the gated invariants ----------------------------------------------
    y_oracle = ref.pann_matmul_ref(x_q2, packed["planes_pos"],
                                   packed["planes_neg"], s_x,
                                   packed["gamma"])
    y_kernel_fused = _pm.pann_matmul(
        x_q2, packed["planes_pos"], packed["planes_neg"], s_x,
        packed["gamma"], interpret=interpret)
    y_kernel_planes = _pm.pann_matmul(
        x_q2, packed["planes_pos"], packed["planes_neg"], s_x,
        packed["gamma"], mode="planes", interpret=interpret)
    y_kernel_packed = pann_matmul_packed(
        x_q2, pp, pn, s_x, packed["gamma"], interpret=interpret)
    yu_oracle = ref.unsigned_matmul_ref(x_q, w_q, s_x, s_w)
    yu_kernel = ops.unsigned_matmul(x_q, w_q, s_x, s_w, interpret=interpret)

    invariants = {
        "shape": {"m": m, "k": k, "n": n, "n_planes": p_cnt,
                  "packed_planes": list(pp.shape),
                  "dispatch_planes": list(leaf["w_planes_pos"].shape)},
        "hbm_bytes_per_weight": {
            "f32": 4.0, "bf16": 2.0, "int8_codes": 1.0,
            "planes_int8": float(2 * p_cnt),
            "planes_packed": float(2 * p_cnt) / 8.0,
        },
        "parity": {
            "kernel_fused_vs_oracle": _exact(y_kernel_fused, y_oracle),
            "kernel_planes_vs_oracle": _exact(y_kernel_planes, y_oracle),
            "kernel_packed_vs_oracle": _exact(y_kernel_packed, y_oracle),
            "unsigned_vs_oracle": _exact(yu_kernel, yu_oracle),
            "dispatch_fused_vs_ref": _exact(disp["fused"], disp["ref"]),
            "dispatch_packed_vs_ref": _exact(disp["packed"], disp["ref"]),
        },
    }
    out = {
        "platform": "tpu" if ops.on_tpu() else "cpu",
        "interpret": bool(interpret),
        "timings_us": {kk: round(v, 1) for kk, v in timings.items()},
        "invariants": invariants,
    }
    path = save_json("kernel_bench.json", out)
    print(f"[kernel_bench] wrote {path}")
    if check:
        failures = check_baseline(out)
        if failures:
            for f in failures:
                print(f"[kernel_bench] REGRESSION: {f}")
            raise SystemExit(1)
        print("[kernel_bench] baseline check passed")
    return out


def check_baseline(result: dict, baseline_path: str = BASELINE) -> list[str]:
    """Hard-fail parity / shape / HBM-bytes drift; timings stay advisory."""
    failures = []
    inv = result["invariants"]
    for name, rec in inv["parity"].items():
        if not rec["exact"]:
            failures.append(f"parity broken: {name} "
                            f"(max_abs_diff={rec['max_abs_diff']:g})")
    with open(baseline_path) as f:
        base = json.load(f)["invariants"]
    for section in ("shape", "hbm_bytes_per_weight"):
        if inv[section] != base[section]:
            failures.append(
                f"{section} drifted from baseline: {inv[section]} != "
                f"{base[section]} — refresh {baseline_path} if intended")
    missing = set(base["parity"]) - set(inv["parity"])
    if missing:
        failures.append(f"parity coverage shrank: {sorted(missing)} in the "
                        f"baseline but not measured")
    return failures


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="gate invariants against the committed baseline")
    args = ap.parse_args(argv)
    return run(check=args.check)


if __name__ == "__main__":
    main()
