"""Pallas kernel micro-bench + the CI kernel correctness gate.

Two jobs in one script:

  * timings — median us/call for every kernel and every serving-matmul
    dispatch backend. On CPU the kernels run in interpret mode (emulation),
    so timings are informational only; on TPU (``kernels.ops.on_tpu()``)
    the same script measures the REAL kernels (interpret=False).
  * ``--check`` — gate the platform-independent invariants against the
    committed baseline (benchmarks/baselines/kernel_bench.json): backend
    parity (ref / fused / packed bit-identical through repro.kernels.
    dispatch, for BOTH dynamic and export-frozen calibrated activation
    ranges; raw kernels vs the jnp oracles), artifact shapes, HBM bytes
    per weight per layout, and the per-projection activation HBM traffic
    (``act_hbm_bytes`` — the fused prologue eliminates the int8 code
    round-trip). Any parity or shape/HBM drift hard-fails; timing drift
    never does. Refresh the baseline by copying
    benchmarks/results/kernel_bench.json over it when the kernels
    legitimately change.
  * ``--trajectory`` — append this run's timings to the committed
    BENCH_kernels.json at the repo root, the perf trajectory nightly CI
    extends. ``--check`` diffs the newest same-platform point against the
    previous one and WARNS (never fails) on a slowdown > TRAJ_SLOWDOWN —
    wall-clock noise is advisory; only parity/shape/HBM hard-fail.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.common import emit, save_json, time_call  # noqa: E402
from repro import configs  # noqa: E402
from repro.core import policy as pol  # noqa: E402
from repro.kernels import dispatch, ops, ref  # noqa: E402
from repro.kernels import pann_matmul as _pm  # noqa: E402
from repro.kernels.pann_matmul_packed import (pack_planes,  # noqa: E402
                                              pann_matmul_packed)
from repro.models import serving  # noqa: E402
from repro.models.serving import quantize_params_for_serving  # noqa: E402

BASELINE = os.path.join(os.path.dirname(__file__), "baselines",
                        "kernel_bench.json")
TRAJECTORY = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_kernels.json")
TRAJ_SLOWDOWN = 1.5     # informational warning threshold, never a failure


def _exact(a, b) -> dict:
    """Parity record: bit-identical flag + max abs diff (0.0 when exact)."""
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return {"exact": bool((a == b).all()),
            "max_abs_diff": float(np.abs(a - b).max())}


def run(check: bool = False) -> dict:
    interpret = not ops.on_tpu()     # measure REAL kernels on TPU
    rng = np.random.default_rng(0)
    m, k, n = 256, 512, 256
    x = jnp.abs(jnp.asarray(rng.standard_normal((m, k)), jnp.float32))
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    packed = ops.pann_pack_weights(w, r=2.0)
    p_cnt = int(packed["n_planes"])
    timings: dict[str, float] = {}

    us = time_call(lambda: ops.pann_matmul(x, packed, act_bits=8,
                                           interpret=interpret))
    timings["pann_matmul_fused"] = us
    emit("kernel_pann_matmul_fused", us, f"{m}x{k}x{n} int8 bitplane")

    us = time_call(lambda: ops.pann_matmul(x, packed, act_bits=8,
                                           mode="planes",
                                           interpret=interpret))
    timings["pann_matmul_planes"] = us
    emit("kernel_pann_matmul_planes", us, "literal Eq.10 dataflow")

    x_q = jnp.asarray(rng.integers(0, 127, (m, k)), jnp.int8)
    w_q = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8)
    s_x = jnp.ones((m, 1), jnp.float32)
    s_w = jnp.ones((n,), jnp.float32)
    us = time_call(lambda: ops.unsigned_matmul(x_q, w_q, s_x, s_w,
                                               interpret=interpret))
    timings["unsigned_matmul"] = us
    emit("kernel_unsigned_matmul", us, "Sec.4 split, int32 accum")

    us = time_call(lambda: ops.quantize_act(x, bits=8, interpret=interpret))
    timings["quantize_act"] = us
    emit("kernel_quantize_act", us, "per-row scale + round + clip")

    us = time_call(lambda: ref.quantize_act_ref(x, 8))
    timings["quantize_act_ref"] = us
    emit("kernel_quantize_act_ref", us, "jnp oracle")

    pp = pack_planes(packed["planes_pos"])
    pn = pack_planes(packed["planes_neg"])
    x_q2 = jnp.asarray(rng.integers(0, 128, (m, k)), jnp.int8)
    us = time_call(lambda: pann_matmul_packed(
        x_q2, pp, pn, s_x, packed["gamma"], interpret=interpret))
    timings["pann_matmul_packed"] = us
    emit("kernel_pann_matmul_packed", us,
         f"{p_cnt} planes at 1 bit/weight HBM")

    # --- the dispatch backends (the serving hot path) -----------------------
    cfg = configs.reduced(configs.get_config("llama3-8b"))
    leaf = quantize_params_for_serving(
        {"wq": {"w": w}}, cfg, r=2.0, act_bits=8, pack_planes=True)["wq"]
    xs = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    backends = ("ref", "fused" + (":force" if interpret else ""),
                "packed" + (":force" if interpret else ""))
    disp = {}
    for spec in backends:
        name = spec.split(":")[0]
        us = time_call(lambda spec=spec: dispatch.serving_linear(
            xs, leaf, spec))
        timings[f"dispatch_{name}"] = us
        disp[name] = np.asarray(dispatch.serving_linear(xs, leaf, spec))
        emit(f"kernel_dispatch_{name}", us, "serving_linear backend")

    # export-frozen calibrated ranges: the artifact hoists (act_s, act_z)
    # at build time (models/serving) and the fused prologue must match the
    # ref oracle bit-for-bit against those SAME frozen scalars
    calib = {pol.serving_path(("wq",)): (-1.25, 3.5)}
    leaf_cal = quantize_params_for_serving(
        {"wq": {"w": w}}, cfg, r=2.0, act_bits=8, pack_planes=True,
        calib=calib)["wq"]
    assert "act_s" in leaf_cal, "calibrated artifact missing hoisted act_s"
    disp_cal = {spec.split(":")[0]:
                np.asarray(dispatch.serving_linear(xs, leaf_cal, spec))
                for spec in backends}

    # --- the mmap-able weight store: zero-copy rung views ------------------
    # one store quantized at the max rung budget, every rung a view
    # (DESIGN.md §11). Byte accounting is pure shape math (deterministic,
    # gated); view-vs-materialized parity rides in the parity section.
    ws = serving.build_weight_store({"wq": {"w": w}}, cfg,
                                    {2: (2.0, 8), 6: (16.0, 8)},
                                    pack_planes=True)

    def _naive_bytes(tree):
        return sum(int(np.prod(lf.shape)) * lf.dtype.itemsize
                   for lf in jax.tree_util.tree_leaves(tree)
                   if hasattr(lf, "dtype"))

    def _unique_bytes(*trees):
        seen, total = set(), 0
        for tree in trees:
            for lf in jax.tree_util.tree_leaves(tree):
                if hasattr(lf, "dtype") and id(lf) not in seen:
                    seen.add(id(lf))
                    total += int(np.prod(lf.shape)) * lf.dtype.itemsize
        return total

    store_b = _naive_bytes(ws.store)
    unique_b = _unique_bytes(ws.store, *ws.views.values())
    artifact_bytes = {
        "rungs": sorted(ws.views),
        "store_bytes": float(store_b),
        # what actually lands in HBM: store + per-rung scalars/colsums
        "unique_bytes_all_views": float(unique_b),
        # what legacy per-rung materialization would cost for these rungs
        "materialized_bytes_all_views": float(sum(
            _naive_bytes(serving.materialize_view(v))
            for v in ws.views.values())),
        "per_rung_overhead_bytes": float(unique_b - store_b)
        / max(len(ws.views), 1),
    }

    disp_view = {}
    for rung, view in sorted(ws.views.items()):
        mat = serving.materialize_view(view)
        for spec in backends:
            name = spec.split(":")[0]
            disp_view[f"dispatch_view{rung}_vs_materialized_{name}"] = _exact(
                dispatch.serving_linear(xs, view["wq"], spec),
                dispatch.serving_linear(xs, mat["wq"], spec))
            if name == "ref":
                continue
            # the plane-skip latency claim: the narrow rung predicates the
            # dead planes' DMA + MXU passes off, so view2 should beat
            # view6 on TPU (advisory via the trajectory, like all timings)
            us = time_call(lambda v=view, spec=spec: dispatch.serving_linear(
                xs, v["wq"], spec))
            timings[f"dispatch_view{rung}_{name}"] = us
            emit(f"kernel_dispatch_view{rung}_{name}", us,
                 "rung view (plane skip)" if rung < max(ws.views)
                 else "top rung view (no skip)")

    # --- the gated invariants ----------------------------------------------
    y_oracle = ref.pann_matmul_ref(x_q2, packed["planes_pos"],
                                   packed["planes_neg"], s_x,
                                   packed["gamma"])
    y_kernel_fused = _pm.pann_matmul(
        x_q2, packed["planes_pos"], packed["planes_neg"], s_x,
        packed["gamma"], interpret=interpret)
    y_kernel_planes = _pm.pann_matmul(
        x_q2, packed["planes_pos"], packed["planes_neg"], s_x,
        packed["gamma"], mode="planes", interpret=interpret)
    y_kernel_packed = pann_matmul_packed(
        x_q2, pp, pn, s_x, packed["gamma"], interpret=interpret)
    yu_oracle = ref.unsigned_matmul_ref(x_q, w_q, s_x, s_w)
    yu_kernel = ops.unsigned_matmul(x_q, w_q, s_x, s_w, interpret=interpret)

    invariants = {
        "shape": {"m": m, "k": k, "n": n, "n_planes": p_cnt,
                  "packed_planes": list(pp.shape),
                  "dispatch_planes": list(leaf["w_planes_pos"].shape)},
        "hbm_bytes_per_weight": {
            "f32": 4.0, "bf16": 2.0, "int8_codes": 1.0,
            "planes_int8": float(2 * p_cnt),
            "planes_packed": float(2 * p_cnt) / 8.0,
        },
        # activation-side HBM traffic per projection at this bench shape:
        # the unfused PR-4 path wrote the (m, k) int8 code tensor to HBM
        # and read it back in the matmul; the fused prologue encodes codes
        # tile-locally in VMEM, so fp32 x crosses HBM exactly once and the
        # code round-trip (2 x code_tensor_bytes) disappears
        "act_hbm_bytes": {
            "code_tensor": float(m * k),
            "unfused": float(4 * m * k + 2 * m * k),
            "fused_prologue": float(4 * m * k),
            "saved_per_projection": float(2 * m * k),
        },
        "artifact_bytes": artifact_bytes,
        "parity": {
            **disp_view,
            "kernel_fused_vs_oracle": _exact(y_kernel_fused, y_oracle),
            "kernel_planes_vs_oracle": _exact(y_kernel_planes, y_oracle),
            "kernel_packed_vs_oracle": _exact(y_kernel_packed, y_oracle),
            "unsigned_vs_oracle": _exact(yu_kernel, yu_oracle),
            "dispatch_fused_vs_ref": _exact(disp["fused"], disp["ref"]),
            "dispatch_packed_vs_ref": _exact(disp["packed"], disp["ref"]),
            "dispatch_fused_vs_ref_calib": _exact(disp_cal["fused"],
                                                  disp_cal["ref"]),
            "dispatch_packed_vs_ref_calib": _exact(disp_cal["packed"],
                                                   disp_cal["ref"]),
        },
    }
    out = {
        "platform": "tpu" if ops.on_tpu() else "cpu",
        "interpret": bool(interpret),
        "timings_us": {kk: round(v, 1) for kk, v in timings.items()},
        "invariants": invariants,
    }
    path = save_json("kernel_bench.json", out)
    print(f"[kernel_bench] wrote {path}")
    if check:
        for w_line in trajectory_warnings(out):
            print(f"[kernel_bench] SLOWDOWN (informational): {w_line}")
        failures = check_baseline(out)
        if failures:
            for f in failures:
                print(f"[kernel_bench] REGRESSION: {f}")
            raise SystemExit(1)
        print("[kernel_bench] baseline check passed")
    return out


def check_baseline(result: dict, baseline_path: str = BASELINE) -> list[str]:
    """Hard-fail parity / shape / HBM-bytes drift; timings stay advisory."""
    failures = []
    inv = result["invariants"]
    for name, rec in inv["parity"].items():
        if not rec["exact"]:
            failures.append(f"parity broken: {name} "
                            f"(max_abs_diff={rec['max_abs_diff']:g})")
    with open(baseline_path) as f:
        base = json.load(f)["invariants"]
    sections = ["shape", "hbm_bytes_per_weight"]
    # newer sections gate only once both sides carry them, so a refreshed
    # bench still checks cleanly against an older committed baseline
    sections += [s for s in ("act_hbm_bytes", "artifact_bytes")
                 if s in inv and s in base]
    for section in sections:
        if inv[section] != base[section]:
            failures.append(
                f"{section} drifted from baseline: {inv[section]} != "
                f"{base[section]} — refresh {baseline_path} if intended")
    missing = set(base["parity"]) - set(inv["parity"])
    if missing:
        failures.append(f"parity coverage shrank: {sorted(missing)} in the "
                        f"baseline but not measured")
    return failures


def _load_trajectory(path: str = TRAJECTORY) -> dict:
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
            if isinstance(data.get("points"), list):
                return data
        except (json.JSONDecodeError, OSError):
            pass
    return {"schema": 1,
            "note": "kernel timing trajectory (us/call, medians); appended "
                    "by benchmarks/kernel_bench.py --trajectory in nightly "
                    "CI. Timings are advisory — the hard gates are parity/"
                    "shape/HBM in --check.",
            "points": []}


def trajectory_warnings(result: dict, path: str = TRAJECTORY) -> list[str]:
    """Slope diff vs the newest same-platform trajectory point —
    informational only, never a gate failure."""
    pts = [p for p in _load_trajectory(path)["points"]
           if p.get("platform") == result["platform"]]
    if not pts:
        return []
    prev = pts[-1]["timings_us"]
    warns = []
    for name, us in result["timings_us"].items():
        base_us = prev.get(name)
        if base_us and us > base_us * TRAJ_SLOWDOWN:
            warns.append(f"{name}: {us:.0f}us vs {base_us:.0f}us last point "
                         f"({us / base_us:.2f}x, threshold "
                         f"{TRAJ_SLOWDOWN:.2f}x)")
    return warns


def append_trajectory(result: dict, path: str = TRAJECTORY) -> str:
    traj = _load_trajectory(path)
    traj["points"].append({
        "utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "platform": result["platform"],
        "interpret": result["interpret"],
        "timings_us": result["timings_us"],
    })
    with open(path, "w") as f:
        json.dump(traj, f, indent=1)
        f.write("\n")
    print(f"[kernel_bench] trajectory point {len(traj['points'])} -> {path}")
    return path


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="gate invariants against the committed baseline "
                         "(parity/shape/HBM hard-fail; timing slope vs the "
                         "trajectory warns only)")
    ap.add_argument("--trajectory", action="store_true",
                    help="append this run's timings to the committed "
                         "BENCH_kernels.json trajectory (nightly CI)")
    args = ap.parse_args(argv)
    out = run(check=args.check)
    if args.trajectory:
        append_trajectory(out)
    return out


if __name__ == "__main__":
    main()
