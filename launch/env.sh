# Serving environment for the PANN TPU stack. Source before launching:
#
#     source launch/env.sh
#     PYTHONPATH=src python -m repro.launch.serve --power_ladder 2,4,6 \
#         --backend packed --autotune ...
#
# Every knob is set with ${VAR:-default} so an explicitly exported value
# always wins. The XLA/libtpu flags are only exported when a TPU chip is
# actually attached: XLA's flag parser ABORTS the process on flags its
# build didn't register, so sourcing TPU flags on a CPU host would kill
# every jax program rather than being ignored.

# --- XLA / libtpu (TPU hosts only) -----------------------------------------
if ls /dev/accel* > /dev/null 2>&1 || [ -d /dev/vfio ] \
        || [ -n "${TPU_NAME:-}" ]; then
    # Decode is latency-bound: async collectives + latency-hiding scheduler
    # let the per-layer all-reduce of the Megatron column/row pair overlap
    # the next projection's compute instead of serializing after it.
    _PANN_XLA_FLAGS="--xla_tpu_enable_async_collective_fusion=true"
    _PANN_XLA_FLAGS="${_PANN_XLA_FLAGS} --xla_tpu_enable_async_collective_fusion_fuse_all_gather=true"
    _PANN_XLA_FLAGS="${_PANN_XLA_FLAGS} --xla_latency_hiding_scheduler_rerun=1"
    # The fused-prologue kernels budget ~8 MiB of VMEM scratch per core
    # (kernels/autotune.vmem_bytes); stop XLA from also claiming an
    # oversized scratchpad reservation that would shrink what pallas_call
    # can allocate.
    _PANN_XLA_FLAGS="${_PANN_XLA_FLAGS} --xla_tpu_scoped_vmem_limit_kib=65536"
    export XLA_FLAGS="${XLA_FLAGS:-${_PANN_XLA_FLAGS}}"
    unset _PANN_XLA_FLAGS
fi

# --- allocator -------------------------------------------------------------
# Serving engines hold N ladder variants resident; the default 75%
# preallocation plus the BFC allocator's growth policy fragments against
# the variant cache. Preallocate a fixed 85% once and keep the allocator
# platform-default (bfc) — deterministic footprint, no growth stalls.
export XLA_PYTHON_CLIENT_PREALLOCATE="${XLA_PYTHON_CLIENT_PREALLOCATE:-true}"
export XLA_PYTHON_CLIENT_MEM_FRACTION="${XLA_PYTHON_CLIENT_MEM_FRACTION:-0.85}"

# --- repro knobs -----------------------------------------------------------
# Persistent autotune cache (kernels/autotune): per-device-kind block shapes
# survive restarts. Point at a shared path to reuse tuning across hosts of
# the same TPU generation.
export REPRO_AUTOTUNE_CACHE="${REPRO_AUTOTUNE_CACHE:-${HOME}/.cache/repro_pann/autotune.json}"
